//! Integration tests of the `.vpr` program format (ISSUE 7): DSL -> text ->
//! parse round trips are bit-identical on both backends, every committed
//! golden in `examples/programs/` parses and re-emits stably, malformed
//! inputs are typed errors naming the line, and loaded programs are
//! first-class workloads (servable, sweepable, cache-deduped by `CellKey`).

use std::path::PathBuf;

use vima_sim::config::SystemConfig;
use vima_sim::program::{self, parse, ParsedVpr};
use vima_sim::service::{Job, JobStatus, ServiceConfig, SimService};
use vima_sim::sim::simulate;
use vima_sim::sweep::{RunCell, SweepPlan, SweepRunner};
use vima_sim::trace::{Backend, TraceParams};
use vima_sim::workload::{self, programs, WorkloadKind};

fn goldens_dir() -> PathBuf {
    PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/../examples/programs"))
}

/// DSL construction -> `to_vpr` -> `parse` -> bit-identical event streams,
/// on both the VIMA and honest-AVX lowerings.
#[test]
fn dsl_round_trips_bit_identically_on_both_backends() {
    for (p, label) in [(programs::saxpy(16), "saxpy"), (programs::softmax(8), "softmax")] {
        let text = p.to_vpr(label).unwrap();
        let rt: ParsedVpr = parse(&text).unwrap();
        assert_eq!(rt.name.as_deref(), Some(label));
        assert_eq!(rt.program.footprint(), p.footprint());
        assert_eq!(rt.program.events(), p.events());
        for backend in [Backend::Vima, Backend::Avx] {
            assert_eq!(
                rt.program.build_for(backend).unwrap(),
                p.build_for(backend).unwrap(),
                "{label}/{backend}: round-trip must be bit-identical"
            );
        }
    }
}

/// Every golden the Python emitter committed parses, re-emits, and
/// re-parses to the same event streams — emit/parse is a fixed point.
#[test]
fn committed_goldens_round_trip() {
    let mut paths: Vec<_> = std::fs::read_dir(goldens_dir())
        .expect("examples/programs/ must exist")
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().is_some_and(|x| x == "vpr"))
        .collect();
    paths.sort();
    assert!(paths.len() >= 8, "expected the 8 committed goldens, found {}", paths.len());
    for path in paths {
        let label = path.file_stem().unwrap().to_string_lossy().into_owned();
        let src = std::fs::read_to_string(&path).unwrap();
        let first = parse(&src).unwrap_or_else(|e| panic!("{label}: {e}"));
        assert!(first.name.is_some(), "{label}: goldens carry a name directive");
        assert!(first.description.is_some(), "{label}: goldens carry a desc directive");
        let re_emitted = first.program.to_vpr("").unwrap();
        let second = parse(&re_emitted).unwrap_or_else(|e| panic!("{label} re-parse: {e}"));
        for backend in [Backend::Vima, Backend::Avx] {
            assert_eq!(
                first.program.build_for(backend).unwrap(),
                second.program.build_for(backend).unwrap(),
                "{label}/{backend}: emit/parse must be a fixed point"
            );
        }
    }
}

/// The Python emitter's saxpy/softmax goldens lower bit-identically to the
/// in-crate DSL constructions they mirror — the cross-language contract.
#[test]
fn python_goldens_match_the_rust_dsl() {
    for (file, dsl) in
        [("saxpy.vpr", programs::saxpy(256)), ("softmax.vpr", programs::softmax(256))]
    {
        let src = std::fs::read_to_string(goldens_dir().join(file)).unwrap();
        let parsed = parse(&src).unwrap();
        assert_eq!(parsed.program.footprint(), dsl.footprint(), "{file}");
        for backend in [Backend::Vima, Backend::Avx] {
            assert_eq!(
                parsed.program.build_for(backend).unwrap(),
                dsl.build_for(backend).unwrap(),
                "{file}/{backend}: python emitter must match the Rust DSL bit-exactly"
            );
        }
    }
}

/// Malformed inputs produce typed errors naming the offending line — never
/// panics, and never a silently-wrong program.
#[test]
fn malformed_inputs_name_their_line() {
    let cases: &[(&str, &str, &str)] = &[
        ("no magic", "alloc a 8192\nvim2k_sets -> a\n", "vpr 1"),
        ("bad version", "vpr 9\n", "version"),
        (
            "unclosed vloop",
            "vpr 1\nalloc a 8192\nvloop 4\nvim2k_movs a -> a\n",
            "line 3",
        ),
        (
            "header after body",
            "vpr 1\nalloc a 8192\nvector_bytes 256\n",
            "header",
        ),
        (
            "duplicate alloc",
            "vpr 1\nalloc a 8192\nalloc a 8192\n",
            "duplicate allocation name `a`",
        ),
        (
            "unknown allocation",
            "vpr 1\nalloc a 8192\nvim2k_movs b -> a\n",
            "unknown allocation `b`",
        ),
        (
            "out-of-footprint walk",
            "vpr 1\nalloc a 8192\nvloop 4\nvim2k_movs a:8192 -> a\nend\n",
            "out-of-footprint",
        ),
        (
            "missing dst",
            "vpr 1\nalloc a 8192\nvim2k_movs a\n",
            "requires a destination",
        ),
        (
            "dst on a reduction",
            "vpr 1\nalloc a 8192\nvim2k_dots a a -> a\n",
            "takes no `-> dst`",
        ),
        (
            "bad arity",
            "vpr 1\nalloc a 8192\nvim2k_adds a -> a\n",
            "expects 2 source operand(s), got 1",
        ),
        (
            "footprint mismatch",
            "vpr 1\nfootprint 1\nalloc a 8192\nvim2k_sets -> a\n",
            "allocations total 8192",
        ),
        (
            "unknown statement",
            "vpr 1\nalloc a 8192\nvim9k_huge a -> a\n",
            "unknown statement `vim9k_huge`",
        ),
    ];
    for (label, src, needle) in cases {
        let e = parse(src).unwrap_err().to_string();
        assert!(e.contains(needle), "{label}: error {e:?} must mention {needle:?}");
    }
}

/// Loading the same program twice is a clean registry error, and a loaded
/// program simulates end to end through the public `simulate` path.
#[test]
fn loaded_programs_register_once_and_simulate() {
    let text = programs::saxpy(8).to_vpr("it-vpr-sim").unwrap();
    let id = program::load_str(&text, "unused").unwrap();
    assert_eq!(workload::name(id), "it-vpr-sim");
    assert_eq!(workload::get(id).unwrap().kind(), WorkloadKind::LoadedVpr);
    let e = program::load_str(&text, "unused").unwrap_err().to_string();
    assert!(e.contains("already registered"), "{e}");

    let fp = workload::get(id).unwrap().default_footprint();
    let r = simulate(&SystemConfig::default(), TraceParams::new(id, Backend::Vima, fp)).unwrap();
    assert!(r.cycles > 0);
    // saxpy(8): one set + 8 fmadds.
    assert_eq!(r.report.get("vima.instructions"), Some(9.0));
}

/// A loaded `.vpr` workload is servable through `SimService` with correct
/// `CellKey` identity: duplicate jobs dedup to one run, distinct loaded
/// programs stay distinct.
#[test]
fn loaded_programs_are_servable_with_cellkey_dedup() {
    let a = program::load_str(&programs::saxpy(4).to_vpr("it-vpr-a").unwrap(), "a").unwrap();
    let b = program::load_str(&programs::softmax(4).to_vpr("it-vpr-b").unwrap(), "b").unwrap();
    let fp = |id| workload::get(id).unwrap().default_footprint();

    let svc = SimService::new(ServiceConfig { jobs: 2, ..ServiceConfig::default() });
    let job_a = Job::new(TraceParams::new(a, Backend::Vima, fp(a)));
    let first = svc.submit(job_a.clone());
    let r1 = first.wait().unwrap();
    // The same job again is already Done at submission — pure cache hit.
    let dup = svc.submit(job_a);
    assert_eq!(dup.status(), JobStatus::Done);
    let r2 = dup.wait().unwrap();
    assert_eq!(r1.cycles, r2.cycles);
    assert_eq!(svc.stats().unique_runs, 1);

    // A different loaded program occupies a different CellKey.
    svc.submit(Job::new(TraceParams::new(b, Backend::Vima, fp(b)))).wait().unwrap();
    assert_eq!(svc.stats().unique_runs, 2);
}

/// Loaded programs ride the sweep engine like any registered workload:
/// identical cells dedup, both backends simulate.
#[test]
fn loaded_programs_are_sweepable() {
    use vima_sim::prelude::SizedWorkload;
    program::load_str(&programs::saxpy(6).to_vpr("it-vpr-sweep").unwrap(), "x").unwrap();
    let w = SizedWorkload::custom("it-vpr-sweep").unwrap();

    let mut plan = SweepPlan::new();
    let first = plan.push(RunCell::new(w, Backend::Vima));
    let dup = plan.push(RunCell::new(w, Backend::Vima));
    let avx = plan.push(RunCell::new(w, Backend::Avx));
    let runner = SweepRunner::new(2);
    let res = runner.run(&SystemConfig::default(), &plan).unwrap();

    assert_eq!(res[first].cycles, res[dup].cycles);
    assert!(res[avx].cycles > 0);
    let stats = runner.stats();
    assert_eq!(stats.cells, 3);
    assert_eq!(stats.unique_runs, 2, "identical loaded-vpr cells simulate once");
    assert_eq!(stats.cache_hits, 1);
}

/// `load_dir` on the committed goldens registers all of them (deterministic
/// sorted order) and each one streams on both of its backends.
#[test]
fn golden_directory_loads_and_streams() {
    let ids = program::load_dir(goldens_dir()).unwrap();
    assert!(ids.len() >= 8, "expected >= 8 goldens, loaded {}", ids.len());
    for id in ids {
        let w = workload::get(id).unwrap();
        assert_eq!(w.kind(), WorkloadKind::LoadedVpr, "{}", w.name());
        for &backend in w.backends() {
            let p = TraceParams::new(id, backend, w.default_footprint());
            assert!(
                p.stream().unwrap().next().is_some(),
                "{}/{backend} must produce events",
                w.name()
            );
        }
    }
    // Loading the directory again trips the duplicate-name registry guard.
    let e = program::load_dir(goldens_dir()).unwrap_err().to_string();
    assert!(e.contains("already registered"), "{e}");
}
