//! Integration tests of the open workload API (ISSUE 2): registry
//! round-trips, typed error paths where the old enum dispatch panicked,
//! streaming-program equivalence with eager builds, and sweep-cache dedup
//! across identical custom workloads.

use vima_sim::config::SystemConfig;
use vima_sim::intrinsics::VimaProgram;
use vima_sim::isa::TraceEvent;
use vima_sim::sim::{simulate, simulate_threads};
use vima_sim::sweep::{RunCell, SweepPlan, SweepRunner};
use vima_sim::trace::{Backend, KernelId, TraceParams};
use vima_sim::workload::{self, WorkloadId};
use vima_sim::prelude::SizedWorkload;

/// register -> resolve -> stream: the full round trip for a user program.
#[test]
fn registry_roundtrip_for_custom_program() {
    let mut p = VimaProgram::new();
    let vb = p.vector_bytes() as u64;
    let a = p.alloc(8 * vb);
    let b = p.alloc(8 * vb);
    let c = p.alloc(8 * vb);
    p.vloop(8, |l| l.vim2k_muls(a.walk(vb), b.walk(vb), c.walk(vb)));
    let footprint = p.footprint();
    let events = p.events();

    let id = p.register("t-roundtrip").unwrap();
    assert_eq!(workload::resolve("t-roundtrip").unwrap(), id);
    assert_eq!(workload::resolve("T-Roundtrip").unwrap(), id, "case-insensitive");

    let params = TraceParams::new(id, Backend::Vima, footprint);
    let got: Vec<TraceEvent> = params.stream().unwrap().collect();
    assert_eq!(got.len() as u64, events);
    // ...and through the simulator, by the same identity.
    let r = simulate(&SystemConfig::default(), params).unwrap();
    assert!(r.cycles > 0);
    assert_eq!(r.report.get("vima.instructions"), Some(8.0));
}

#[test]
fn duplicate_registration_is_an_error() {
    VimaProgram::new().register("t-dup").unwrap();
    let e = VimaProgram::new().register("T-DUP").unwrap_err().to_string();
    assert!(e.contains("already registered"), "{e}");
}

/// The paper kernels resolve through the same registry the CLI uses, and
/// every supported (kernel, backend) pair still streams.
#[test]
fn paper_kernels_stream_through_the_registry() {
    for name in ["memset", "memcopy", "vecsum", "stencil", "matmul", "knn", "mlp"] {
        let id = workload::resolve(name).unwrap();
        let w = workload::get(id).unwrap();
        for &b in w.backends() {
            let p = TraceParams::new(id, b, 2 << 20);
            assert!(
                p.stream().unwrap().next().is_some(),
                "{name}/{b} must produce events"
            );
        }
    }
}

/// Unsupported backends and bad parameters are typed errors end to end
/// (params, simulate, sweep) — the old dispatch panicked.
#[test]
fn error_paths_are_typed_not_panics() {
    let cfg = SystemConfig::default();

    // HIVE gap on a paper kernel.
    let p = TraceParams::new(KernelId::Mlp, Backend::Hive, 4 << 20);
    assert!(p.check().is_err());
    let e = simulate(&cfg, p).unwrap_err().to_string();
    assert!(e.contains("HIVE") && e.contains("MLP"), "{e}");

    // Programs have no HIVE lowering either.
    let saxpy = workload::resolve("saxpy").unwrap();
    let fp = workload::get(saxpy).unwrap().default_footprint();
    let e = simulate(&cfg, TraceParams::new(saxpy, Backend::Hive, fp))
        .unwrap_err()
        .to_string();
    assert!(e.contains("HIVE"), "{e}");

    // A bad footprint for a fixed-structure program workload.
    let e = simulate(&cfg, TraceParams::new(saxpy, Backend::Vima, fp + 1))
        .unwrap_err()
        .to_string();
    assert!(e.contains("footprint"), "{e}");

    // A sweep containing a bad cell fails fast with context.
    let mut plan = SweepPlan::new();
    plan.push(RunCell::new(
        SizedWorkload { workload: KernelId::Knn.into(), footprint: 4 << 20, size_label: "x" },
        Backend::Hive,
    ));
    let e = SweepRunner::new(1).run(&cfg, &plan).unwrap_err().to_string();
    assert!(e.contains("sweep cell") && e.contains("HIVE"), "{e}");
}

/// A streamed program (lazy chunker) is event-for-event identical to the
/// eager `build()` expansion — the old eager-vector behavior is a special
/// case of the new streaming DSL.
#[test]
fn streaming_program_equals_eager_build() {
    let build_one = || {
        let mut p = VimaProgram::new();
        let vb = p.vector_bytes() as u64;
        let acc = p.alloc(vb);
        let data = p.alloc(32 * vb);
        p.vim2k_sets(acc);
        p.vloop(32, |l| {
            l.vim2k_adds(data.walk(vb), acc, acc);
            l.vim2k_dots(data.walk(vb), acc);
        });
        p.host_load(acc, 8);
        p
    };
    let eager: Vec<TraceEvent> = build_one().build();
    let streamed: Vec<TraceEvent> =
        build_one().stream_for(Backend::Vima).unwrap().collect();
    assert_eq!(eager, streamed);

    // The simulator sees identical results from either form.
    let cfg = SystemConfig::default();
    let mut m = vima_sim::sim::Machine::new(&cfg, 1).unwrap();
    let a = m.run(vec![build_one().into_stream()]).unwrap();
    let mut m = vima_sim::sim::Machine::new(&cfg, 1).unwrap();
    let b = m.run(vec![build_one().into_stream()]).unwrap();
    assert_eq!(a.cycles, b.cycles);
    assert_eq!(a.report, b.report);
}

/// Identical custom-workload cells hit the sweep result cache — workload
/// identity (TraceParams: Eq + Hash) keys the cache directly.
#[test]
fn sweep_cache_dedups_identical_custom_workloads() {
    let mut prog = VimaProgram::new();
    let vb = prog.vector_bytes() as u64;
    let a = prog.alloc(16 * vb);
    let b = prog.alloc(16 * vb);
    prog.vloop(16, |l| l.vim2k_movs(a.walk(vb), b.walk(vb)));
    let id = prog.register("t-dedup").unwrap();

    let w = SizedWorkload::custom("t-dedup").unwrap();
    assert_eq!(w.workload, id);

    let cfg = SystemConfig::default();
    let runner = SweepRunner::new(2);
    let mut plan = SweepPlan::new();
    let first = plan.push(RunCell::new(w, Backend::Vima));
    let dup = plan.push(RunCell::new(w, Backend::Vima));
    let avx = plan.push(RunCell::new(w, Backend::Avx));
    let res = runner.run(&cfg, &plan).unwrap();

    assert_eq!(res[first].cycles, res[dup].cycles);
    assert_ne!(res[first].cycles, res[avx].cycles, "backends must differ");
    let stats = runner.stats();
    assert_eq!(stats.cells, 3);
    assert_eq!(stats.unique_runs, 2, "identical custom cells simulate once");
    assert_eq!(stats.cache_hits, 1);

    // A second plan over the same workload is served entirely from cache.
    runner.run(&cfg, &plan).unwrap();
    assert_eq!(runner.stats().unique_runs, 2);
}

/// The shipped example programs run data-parallel and keep their trace
/// volume under thread slicing.
#[test]
fn builtin_programs_run_multithreaded() {
    let cfg = SystemConfig::default();
    let saxpy = workload::resolve("saxpy").unwrap();
    let fp = workload::get(saxpy).unwrap().default_footprint();
    let p = TraceParams::new(saxpy, Backend::Vima, fp);
    let t1 = simulate_threads(&cfg, p, 1).unwrap();
    let t2 = simulate_threads(&cfg, p, 2).unwrap();
    let instrs = |r: &vima_sim::sim::SimResult| r.report.get("vima.instructions").unwrap();
    assert_eq!(instrs(&t1), instrs(&t2), "slicing must conserve instructions");
    assert!(t2.cycles > 0);
}

/// WorkloadId/KernelId interop: the paper kernels keep their identity.
#[test]
fn kernel_ids_convert_to_workload_ids() {
    let id: WorkloadId = KernelId::Stencil.into();
    assert_eq!(workload::name(id), "Stencil");
    let a = TraceParams::new(KernelId::Stencil, Backend::Vima, 1 << 20);
    let b = TraceParams::new(id, Backend::Vima, 1 << 20);
    assert_eq!(a, b);
}
