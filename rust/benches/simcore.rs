//! Simulator micro-benchmarks — the §Perf instrument for the L3 hot paths:
//! trace generation rate, core-model µop throughput, memory-system access
//! rate, VIMA device instruction rate, and whole-stack events/second.

use vima_sim::cache::MemorySystem;
use vima_sim::config::SystemConfig;
use vima_sim::coordinator::workloads::{SizeScale, WorkloadSet};
use vima_sim::cpu::Core;
use vima_sim::isa::{FuType, Uop, VDtype, VimaInstr, VimaOp, NO_REG};
use vima_sim::mem3d::Mem3D;
use vima_sim::sim::{run_on, simulate, Machine};
use vima_sim::sweep::{RunCell, SweepPlan, SweepRunner};
use vima_sim::trace::{Backend, KernelId, TraceParams};
use vima_sim::util::bench;
use vima_sim::vima::VimaDevice;

fn main() {
    let cfg = SystemConfig::default();

    bench::section("trace generation");
    let n_events = TraceParams::new(KernelId::VecSum, Backend::Avx, 8 << 20).stream().unwrap().count();
    let r = bench::bench("trace_gen_vecsum_avx_8mb", 5, || {
        TraceParams::new(KernelId::VecSum, Backend::Avx, 8 << 20).stream().unwrap().count()
    });
    bench::metric("trace_gen.events_per_sec", n_events as f64 / r.mean_s, "ev/s");

    bench::section("core model (L1-hit ALU/load mix)");
    let uops: Vec<Uop> = (0..100_000u64)
        .map(|i| match i % 4 {
            0 => Uop::load(0x400, 0x1000 + (i % 64) * 64, 64, 1),
            1 => Uop::alu(0x408, FuType::IntAlu, [1, NO_REG, NO_REG], 2),
            2 => Uop::alu(0x410, FuType::FpMul, [2, NO_REG, NO_REG], 3),
            _ => Uop::branch(0x418, true),
        })
        .collect();
    let r = bench::bench("core_100k_uops", 10, || {
        let mut core = Core::new(0, &cfg.core);
        let mut mem = MemorySystem::new(&cfg, 1).unwrap();
        for u in &uops {
            core.run_uop(u, &mut mem);
        }
        core.now()
    });
    bench::metric("core.uops_per_sec", 100_000.0 / r.mean_s, "uops/s");

    bench::section("memory system (streaming misses)");
    let r = bench::bench("memsys_100k_miss_stream", 10, || {
        let mut mem = MemorySystem::new(&cfg, 1).unwrap();
        let mut t = 0;
        for i in 0..100_000u64 {
            t = mem.access(0, i * 64, false, t).done.saturating_sub(60);
        }
        t
    });
    bench::metric("memsys.accesses_per_sec", 100_000.0 / r.mean_s, "acc/s");

    bench::section("3D memory (raw vault/bank model)");
    let r = bench::bench("mem3d_100k_vima_subreqs", 10, || {
        let mut m = Mem3D::new(&cfg.mem, cfg.core.freq_ghz).unwrap();
        let mut done = 0u64;
        for i in 0..100_000u64 {
            done = m.vima_access(i * 64, false, done.saturating_sub(40)).done;
        }
        done
    });
    bench::metric("mem3d.subreqs_per_sec", 100_000.0 / r.mean_s, "req/s");

    bench::section("VIMA device (instruction pipeline)");
    let r = bench::bench("vima_10k_instructions", 10, || {
        let mut v = VimaDevice::new(&cfg.vima, 1, cfg.core.freq_ghz);
        let mut m = Mem3D::new(&cfg.mem, cfg.core.freq_ghz).unwrap();
        let mut t = 0;
        for i in 0..10_000u64 {
            let base = (i % 512) * 0x6000;
            let instr = VimaInstr::new(
                VimaOp::Add,
                VDtype::F32,
                &[base, base + 0x2000],
                Some(base + 0x4000),
                8192,
            );
            t = v.execute(&instr, t, &mut m).unwrap();
        }
        t
    });
    bench::metric("vima.instrs_per_sec", 10_000.0 / r.mean_s, "instr/s");

    bench::section("whole stack (end-to-end simulate)");
    let p = TraceParams::new(KernelId::VecSum, Backend::Avx, 8 << 20);
    let events = p.stream().unwrap().count() as f64;
    // Drive the machine directly: `simulate` now goes through the service
    // result cache, which would turn every timed iteration after the first
    // into a cache hit and fake a massive speedup in the perf record.
    let mut sim_machine = Machine::new(&cfg, 1).unwrap();
    let r = bench::bench("simulate_vecsum_avx_8mb", 5, || {
        sim_machine.reset();
        run_on(&mut sim_machine, p).unwrap().cycles
    });
    bench::metric("sim.end_to_end_events_per_sec", events / r.mean_s, "ev/s");
    let sim_cycles = simulate(&cfg, p).unwrap().cycles as f64;
    bench::metric("sim.simulated_cycles_per_sec", sim_cycles / r.mean_s, "cy/s");

    bench::section("chunked vs reference execution (events/sec)");
    let mut m = Machine::new(&cfg, 1).unwrap();
    let r_ref = bench::bench("run_reference_vecsum_avx_8mb", 5, || {
        m.reset();
        m.run_reference(vec![p.stream().unwrap()]).unwrap().cycles
    });
    let r_chunk = bench::bench("run_chunked_vecsum_avx_8mb", 5, || {
        m.reset();
        m.run(vec![p.stream().unwrap()]).unwrap().cycles
    });
    bench::metric("sim.reference_events_per_sec", events / r_ref.mean_s, "ev/s");
    bench::metric("sim.chunked_events_per_sec", events / r_chunk.mean_s, "ev/s");
    bench::metric("sim.chunked_speedup_vs_reference", r_ref.mean_s / r_chunk.mean_s, "x");

    bench::section("sweep engine (fig2 grid: 27 cells, deduped + parallel)");
    let mut plan = SweepPlan::new();
    for w in WorkloadSet::fig2(SizeScale::Quick) {
        for b in [Backend::Avx, Backend::Hive, Backend::Vima] {
            plan.push(RunCell::new(w, b));
        }
    }
    // fresh runner per iteration: measures real simulation throughput, not
    // cache lookups
    let r = bench::bench("sweep_fig2_grid", 1, || SweepRunner::new(0).run(&cfg, &plan).unwrap().len());
    bench::metric("sweep.cells_per_sec", plan.len() as f64 / r.mean_s, "cells/s");
}
