//! Bench: regenerate Fig. 3 (VIMA speedup vs single-thread AVX over all
//! seven kernels x three dataset sizes).
//!
//! `VIMA_BENCH_SCALE=paper cargo bench --bench fig3_single_thread` runs the
//! full Sec. IV sizes (several minutes — MatMul/kNN dominate).

use vima_sim::config::SystemConfig;
use vima_sim::coordinator::workloads::SizeScale;
use vima_sim::coordinator::Experiment;
use vima_sim::util::bench;

fn scale() -> SizeScale {
    match std::env::var("VIMA_BENCH_SCALE").as_deref() {
        Ok("paper") => SizeScale::Paper,
        _ => SizeScale::Quick,
    }
}

/// Sweep worker threads: `VIMA_BENCH_JOBS` (0/unset = all cores).
fn jobs() -> usize {
    std::env::var("VIMA_BENCH_JOBS").ok().and_then(|s| s.parse().ok()).unwrap_or(0)
}

fn main() {
    bench::section("Fig. 3 reproduction (single-thread speedup matrix)");
    // Fresh Experiment per iteration: the persistent result cache would
    // otherwise turn every timed run after the warm-up into pure cache hits.
    let mut last = None;
    bench::bench("fig3_full_experiment", 1, || {
        let exp = Experiment::with_jobs(SystemConfig::default(), scale(), jobs());
        last = Some((exp.fig3().unwrap(), exp.sweep_stats()));
    });
    let (table, st) = last.unwrap();
    println!("\n{}", table.to_markdown());
    let mut max = 0f64;
    for (label, vals) in &table.rows {
        bench::metric(&format!("fig3.{label}.speedup"), vals[0], "x");
        max = max.max(vals[0]);
    }
    bench::metric("fig3.max_speedup", max, "x (paper headline: up to 26x)");

    bench::metric("sweep.cells", st.cells as f64, "planned");
    bench::metric("sweep.unique_runs", st.unique_runs as f64, "simulated (deduped)");
    bench::metric("sweep.cache_hits", st.cache_hits as f64, "served from cache");
}
