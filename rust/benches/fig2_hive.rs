//! Bench: regenerate Fig. 2 (HIVE vs VIMA vs AVX on MemSet/VecSum/Stencil)
//! and report the wall time of the whole experiment.
//!
//! `VIMA_BENCH_SCALE=paper cargo bench --bench fig2_hive` runs the full
//! Sec. IV dataset sizes; the default is the 1/16 quick scale.

use vima_sim::config::SystemConfig;
use vima_sim::coordinator::workloads::SizeScale;
use vima_sim::coordinator::Experiment;
use vima_sim::util::bench;

fn scale() -> SizeScale {
    match std::env::var("VIMA_BENCH_SCALE").as_deref() {
        Ok("paper") => SizeScale::Paper,
        _ => SizeScale::Quick,
    }
}

/// Sweep worker threads: `VIMA_BENCH_JOBS` (0/unset = all cores).
fn jobs() -> usize {
    std::env::var("VIMA_BENCH_JOBS").ok().and_then(|s| s.parse().ok()).unwrap_or(0)
}

fn main() {
    bench::section("Fig. 2 reproduction (HIVE vs VIMA vs AVX)");
    // Fresh Experiment per iteration: the persistent result cache would
    // otherwise turn every timed run after the warm-up into pure cache hits.
    let mut last = None;
    bench::bench("fig2_full_experiment", 3, || {
        let exp = Experiment::with_jobs(SystemConfig::default(), scale(), jobs());
        last = Some((exp.fig2().unwrap(), exp.sweep_stats()));
    });
    let (table, st) = last.unwrap();
    println!("\n{}", table.to_markdown());
    // Headline assertions from the paper's Fig. 2 discussion.
    for (label, vals) in &table.rows {
        bench::metric(&format!("fig2.{label}.hive_speedup"), vals[0], "x");
        bench::metric(&format!("fig2.{label}.vima_speedup"), vals[1], "x");
    }

    bench::metric("sweep.cells", st.cells as f64, "planned");
    bench::metric("sweep.unique_runs", st.unique_runs as f64, "simulated (deduped)");
    bench::metric("sweep.cache_hits", st.cache_hits as f64, "served from cache");
}
