//! Bench: regenerate Fig. 2 (HIVE vs VIMA vs AVX on MemSet/VecSum/Stencil)
//! and report the wall time of the whole experiment.
//!
//! `VIMA_BENCH_SCALE=paper cargo bench --bench fig2_hive` runs the full
//! Sec. IV dataset sizes; the default is the 1/16 quick scale.

use vima_sim::config::SystemConfig;
use vima_sim::coordinator::workloads::SizeScale;
use vima_sim::coordinator::Experiment;
use vima_sim::util::bench;

fn scale() -> SizeScale {
    match std::env::var("VIMA_BENCH_SCALE").as_deref() {
        Ok("paper") => SizeScale::Paper,
        _ => SizeScale::Quick,
    }
}

fn main() {
    bench::section("Fig. 2 reproduction (HIVE vs VIMA vs AVX)");
    let exp = Experiment::new(SystemConfig::default(), scale());
    let mut last = None;
    bench::bench("fig2_full_experiment", 3, || {
        last = Some(exp.fig2());
    });
    let table = last.unwrap();
    println!("\n{}", table.to_markdown());
    // Headline assertions from the paper's Fig. 2 discussion.
    for (label, vals) in &table.rows {
        bench::metric(&format!("fig2.{label}.hive_speedup"), vals[0], "x");
        bench::metric(&format!("fig2.{label}.vima_speedup"), vals[1], "x");
    }
}
