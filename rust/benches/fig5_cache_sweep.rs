//! Bench: regenerate Fig. 5 (VIMA speedup for cache sizes 16..256 KB) plus
//! the Sec. III-C ablations (vector size, stop-and-go).
//!
//! `VIMA_BENCH_SCALE=paper cargo bench --bench fig5_cache_sweep` for the
//! paper's largest dataset sizes.

use vima_sim::config::SystemConfig;
use vima_sim::coordinator::workloads::SizeScale;
use vima_sim::coordinator::Experiment;
use vima_sim::util::bench;

fn scale() -> SizeScale {
    match std::env::var("VIMA_BENCH_SCALE").as_deref() {
        Ok("paper") => SizeScale::Paper,
        _ => SizeScale::Quick,
    }
}

fn main() {
    bench::section("Fig. 5 reproduction (VIMA cache-size sweep) + ablations");
    let exp = Experiment::new(SystemConfig::default(), scale());

    let mut fig5 = None;
    bench::bench("fig5_cache_sweep", 1, || {
        fig5 = Some(exp.fig5());
    });
    let fig5 = fig5.unwrap();
    println!("\n{}", fig5.to_markdown());

    let mut ab1 = None;
    bench::bench("ablation_vector_size", 1, || {
        ab1 = Some(exp.ablation_vector_size());
    });
    println!("\n{}", ab1.unwrap().to_markdown());

    let mut ab2 = None;
    bench::bench("ablation_stop_and_go", 1, || {
        ab2 = Some(exp.ablation_stop_and_go());
    });
    let ab2 = ab2.unwrap();
    println!("\n{}", ab2.to_markdown());
    for (label, vals) in &ab2.rows {
        bench::metric(&format!("stop_and_go.{label}.gap_bubble"), vals[1], "% (paper: 2-4%)");
        bench::metric(
            &format!("stop_and_go.{label}.pipelining_bound"),
            vals[2],
            "% (precise-exception upper bound)",
        );
    }
}
