//! Bench: regenerate Fig. 5 (VIMA speedup for cache sizes 16..256 KB) plus
//! the Sec. III-C ablations (vector size, stop-and-go).
//!
//! `VIMA_BENCH_SCALE=paper cargo bench --bench fig5_cache_sweep` for the
//! paper's largest dataset sizes.

use vima_sim::config::SystemConfig;
use vima_sim::coordinator::workloads::SizeScale;
use vima_sim::coordinator::Experiment;
use vima_sim::util::bench;

fn scale() -> SizeScale {
    match std::env::var("VIMA_BENCH_SCALE").as_deref() {
        Ok("paper") => SizeScale::Paper,
        _ => SizeScale::Quick,
    }
}

/// Sweep worker threads: `VIMA_BENCH_JOBS` (0/unset = all cores).
fn jobs() -> usize {
    std::env::var("VIMA_BENCH_JOBS").ok().and_then(|s| s.parse().ok()).unwrap_or(0)
}

fn main() {
    bench::section("Fig. 5 reproduction (VIMA cache-size sweep) + ablations");
    // Fresh Experiment per timed closure: the persistent result cache would
    // otherwise turn every run after the warm-up into pure cache hits.
    let mut last = None;
    bench::bench("fig5_cache_sweep", 1, || {
        let exp = Experiment::with_jobs(SystemConfig::default(), scale(), jobs());
        last = Some((exp.fig5().unwrap(), exp.sweep_stats()));
    });
    let (fig5, st) = last.unwrap();
    println!("\n{}", fig5.to_markdown());

    let mut ab1 = None;
    bench::bench("ablation_vector_size", 1, || {
        let exp = Experiment::with_jobs(SystemConfig::default(), scale(), jobs());
        ab1 = Some(exp.ablation_vector_size().unwrap());
    });
    println!("\n{}", ab1.unwrap().to_markdown());

    let mut ab2 = None;
    bench::bench("ablation_stop_and_go", 1, || {
        let exp = Experiment::with_jobs(SystemConfig::default(), scale(), jobs());
        ab2 = Some(exp.ablation_stop_and_go().unwrap());
    });
    let ab2 = ab2.unwrap();
    println!("\n{}", ab2.to_markdown());
    for (label, vals) in &ab2.rows {
        bench::metric(&format!("stop_and_go.{label}.gap_bubble"), vals[1], "% (paper: 2-4%)");
        bench::metric(
            &format!("stop_and_go.{label}.pipelining_bound"),
            vals[2],
            "% (precise-exception upper bound)",
        );
    }

    // fig5 closure only; the ablation experiments above keep their own
    // (discarded) runners so each bench times a cold cache.
    bench::metric("sweep.fig5.cells", st.cells as f64, "planned");
    bench::metric("sweep.fig5.unique_runs", st.unique_runs as f64, "simulated (deduped)");
    bench::metric("sweep.fig5.cache_hits", st.cache_hits as f64, "served from cache");
}
