//! Bench: regenerate Fig. 4 (multithreaded AVX 1..32 cores vs one VIMA
//! device; speedup and energy relative to single-thread AVX).
//!
//! `VIMA_BENCH_SCALE=paper cargo bench --bench fig4_multithread` for the
//! paper's largest dataset sizes.

use vima_sim::config::SystemConfig;
use vima_sim::coordinator::workloads::SizeScale;
use vima_sim::coordinator::Experiment;
use vima_sim::util::bench;

fn scale() -> SizeScale {
    match std::env::var("VIMA_BENCH_SCALE").as_deref() {
        Ok("paper") => SizeScale::Paper,
        _ => SizeScale::Quick,
    }
}

/// Sweep worker threads: `VIMA_BENCH_JOBS` (0/unset = all cores).
fn jobs() -> usize {
    std::env::var("VIMA_BENCH_JOBS").ok().and_then(|s| s.parse().ok()).unwrap_or(0)
}

fn main() {
    bench::section("Fig. 4 reproduction (VIMA vs multithreaded AVX)");
    // Fresh Experiment per iteration: the persistent result cache would
    // otherwise turn every timed run after the warm-up into pure cache hits.
    let mut last = None;
    bench::bench("fig4_full_experiment", 1, || {
        let exp = Experiment::with_jobs(SystemConfig::default(), scale(), jobs());
        last = Some((exp.fig4().unwrap(), exp.sweep_stats()));
    });
    let (table, st) = last.unwrap();
    println!("\n{}", table.to_markdown());
    for (label, _) in &table.rows {
        let vima = table.get(label, "vima_speedup").unwrap();
        let avx16 = table.get(label, "avx16_speedup").unwrap();
        let avx32 = table.get(label, "avx32_speedup").unwrap();
        bench::metric(&format!("fig4.{label}.vima"), vima, "x");
        bench::metric(&format!("fig4.{label}.avx16"), avx16, "x");
        bench::metric(&format!("fig4.{label}.avx32"), avx32, "x");
        bench::metric(
            &format!("fig4.{label}.vima_energy"),
            table.get(label, "vima_energy").unwrap() * 100.0,
            "% of AVX-1T",
        );
    }

    bench::metric("sweep.cells", st.cells as f64, "planned");
    bench::metric("sweep.unique_runs", st.unique_runs as f64, "simulated (deduped)");
    bench::metric("sweep.cache_hits", st.cache_hits as f64, "served from cache");
}
