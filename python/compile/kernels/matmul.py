"""Tiled matrix multiplication as a Pallas kernel (paper Sec. IV-A, *MatMul*).

Hardware adaptation note (DESIGN.md §Hardware-Adaptation): the paper's
MatMul runs on 256 scalar-lane FUs near memory; on a TPU the same insight
(keep operand tiles resident close to the FUs, stream the large matrix once)
maps to MXU-shaped (128, 128) tiles held in VMEM with a K-accumulation grid.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU systolic array edge; also divides the paper's 2048-element vectors.
MXU_TILE = 128


def matmul_tiled(a, b, *, tile_m: int = MXU_TILE, tile_n: int = MXU_TILE, tile_k: int = MXU_TILE):
    """C = A @ B with (tile_m, tile_k) x (tile_k, tile_n) VMEM tiles.

    Grid is (M/tm, N/tn, K/tk); the K axis accumulates into the same output
    block (revisiting grid dimension), zeroed on the first K step.
    """
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"inner dims mismatch: {a.shape} @ {b.shape}")
    for dim, tile, name in ((m, tile_m, "M"), (n, tile_n, "N"), (k, tile_k, "K")):
        if dim % tile != 0:
            raise ValueError(f"{name}={dim} not a multiple of its tile {tile}")

    def kernel(a_ref, b_ref, o_ref):
        @pl.when(pl.program_id(2) == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        o_ref[...] += jnp.dot(a_ref[...], b_ref[...], preferred_element_type=o_ref.dtype)

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        grid=(m // tile_m, n // tile_n, k // tile_k),
        in_specs=[
            pl.BlockSpec((tile_m, tile_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tile_k, tile_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tile_m, tile_n), lambda i, j, kk: (i, j)),
        interpret=True,
    )(a, b)
