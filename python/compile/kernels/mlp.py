"""Multi-Layer Perceptron inference kernel (paper Sec. IV-A, *MLP*).

One dense layer ``relu(W @ x + b)``: the weight matrix streams through the
vector units row-block by row-block while the activation vector ``x`` stays
resident in the VIMA cache (same reuse shape as kNN's test vector).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def mlp_layer(w, x, b, *, rows_per_block: int = 64, relu: bool = True):
    """``relu(W @ x + b)`` with W (H, F), x (F,), b (H,) -> (H,)."""
    h, f = w.shape
    if x.shape != (f,):
        raise ValueError(f"x shape {x.shape} != ({f},)")
    if b.shape != (h,):
        raise ValueError(f"b shape {b.shape} != ({h},)")
    # Narrow output layers (e.g. a 16-class logit head) use a single block.
    rows_per_block = min(rows_per_block, h)
    if h % rows_per_block != 0:
        raise ValueError(f"rows {h} not a multiple of block {rows_per_block}")

    def kernel(w_ref, x_ref, b_ref, o_ref):
        acc = w_ref[...] @ x_ref[...] + b_ref[...]
        o_ref[...] = jnp.maximum(acc, 0) if relu else acc

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((h,), w.dtype),
        grid=(h // rows_per_block,),
        in_specs=[
            pl.BlockSpec((rows_per_block, f), lambda i: (i, 0)),  # weights: streamed
            pl.BlockSpec((f,), lambda i: (0,)),  # activations: cache-resident
            pl.BlockSpec((rows_per_block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((rows_per_block,), lambda i: (i,)),
        interpret=True,
    )(w, x, b)
