"""VIMA vector ALU as Pallas kernels.

The paper (Sec. III-D): "We used 256 parallel vector units, which means that
eight extra cycles are required to fully process the 2048 elements in a
pipelined fashion."  One VIMA instruction therefore is a (grid=8, block=256)
schedule over an 8 KB operand vector.  These kernels reproduce exactly that
decomposition so the lowered HLO is structurally isomorphic to the hardware
the Rust timing model simulates.

Supported element types match Intrinsics-VIMA (Sec. III-B): signed/unsigned
32- and 64-bit integers, and single/double precision floats.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Number of physical vector functional units on the VIMA logic layer.
LANES = 256
# One VIMA instruction operates over an 8 KB data vector (Sec. III-A).
VECTOR_BYTES = 8192


def elements_per_vector(dtype) -> int:
    """Elements in one 8 KB VIMA vector for ``dtype`` (2048 x 32-bit, 1024 x 64-bit)."""
    return VECTOR_BYTES // jnp.dtype(dtype).itemsize


# --- elementwise op tables ------------------------------------------------

BINOPS = {
    "add": lambda a, b: a + b,
    "sub": lambda a, b: a - b,
    "mul": lambda a, b: a * b,
    "div": lambda a, b: a / b,
    "min": jnp.minimum,
    "max": jnp.maximum,
    "and": lambda a, b: a & b,
    "or": lambda a, b: a | b,
    "xor": lambda a, b: a ^ b,
}

INT_ONLY = {"and", "or", "xor"}


def _lane_specs(n_operands: int, lanes: int):
    """BlockSpecs for ``n_operands`` inputs + 1 output, LANES-element blocks."""
    spec = pl.BlockSpec((lanes,), lambda i: (i,))
    return [spec] * n_operands, spec


def _grid_for(n: int, lanes: int) -> int:
    if n % lanes != 0:
        raise ValueError(f"vector length {n} not a multiple of {lanes} lanes")
    return n // lanes


def vima_binop(op: str, a, b, *, lanes: int = LANES):
    """Elementwise binary VIMA instruction over equal-shape 1-D vectors.

    ``op`` is one of ``BINOPS``; integer-only ops reject float operands.
    """
    if op not in BINOPS:
        raise KeyError(f"unknown VIMA binop {op!r}")
    if a.shape != b.shape or a.dtype != b.dtype:
        raise ValueError(f"operand mismatch: {a.shape}/{a.dtype} vs {b.shape}/{b.dtype}")
    if op in INT_ONLY and not jnp.issubdtype(a.dtype, jnp.integer):
        raise TypeError(f"{op} requires integer operands, got {a.dtype}")
    fn = BINOPS[op]

    def kernel(a_ref, b_ref, o_ref):
        o_ref[...] = fn(a_ref[...], b_ref[...])

    in_specs, out_spec = _lane_specs(2, lanes)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        grid=(_grid_for(a.shape[0], lanes),),
        in_specs=in_specs,
        out_specs=out_spec,
        interpret=True,
    )(a, b)


def vima_ternop(a, b, c, *, lanes: int = LANES):
    """Fused multiply-add: ``a * b + c`` (the paper's FU set is alu/mul/div;
    fma composes mul+alu in one pipelined pass, used by MLP/Stencil codes)."""
    def kernel(a_ref, b_ref, c_ref, o_ref):
        o_ref[...] = a_ref[...] * b_ref[...] + c_ref[...]

    in_specs, out_spec = _lane_specs(3, lanes)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        grid=(_grid_for(a.shape[0], lanes),),
        in_specs=in_specs,
        out_specs=out_spec,
        interpret=True,
    )(a, b, c)


def vima_broadcast(value, n: int, dtype, *, lanes: int = LANES):
    """``_vim2K_?mov`` / MemSet primitive: fill an 8 KB vector with a scalar."""
    value = jnp.asarray(value, dtype)

    def kernel(v_ref, o_ref):
        o_ref[...] = jnp.full((lanes,), v_ref[0], dtype)

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n,), dtype),
        grid=(_grid_for(n, lanes),),
        in_specs=[pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=pl.BlockSpec((lanes,), lambda i: (i,)),
        interpret=True,
    )(value.reshape(1))


def vima_copy(a, *, lanes: int = LANES):
    """MemCopy primitive: stream one vector through the lanes unchanged."""
    def kernel(a_ref, o_ref):
        o_ref[...] = a_ref[...]

    in_specs, out_spec = _lane_specs(1, lanes)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        grid=(_grid_for(a.shape[0], lanes),),
        in_specs=in_specs,
        out_specs=out_spec,
        interpret=True,
    )(a)


def _accumulating_reduce(kernel_body, a_args, out_dtype, lanes: int):
    """Shared shell for lane-blocked reductions accumulating into a (1,) output.

    All grid steps map to the same output block; step 0 zeroes it, every step
    adds its partial — the Pallas analogue of the VIMA fill buffer collecting
    partial results over the 8 pipelined beats.
    """
    n = a_args[0].shape[0]

    in_specs = [pl.BlockSpec((lanes,), lambda i: (i,)) for _ in a_args]
    return pl.pallas_call(
        kernel_body,
        out_shape=jax.ShapeDtypeStruct((1,), out_dtype),
        grid=(_grid_for(n, lanes),),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        interpret=True,
    )(*a_args)


def vima_dot(a, b, *, lanes: int = LANES):
    """Dot product of two 8 KB vectors -> scalar (kNN distance / MLP neuron)."""
    def kernel(a_ref, b_ref, o_ref):
        @pl.when(pl.program_id(0) == 0)
        def _init():
            o_ref[...] = jnp.zeros((1,), a.dtype)

        o_ref[...] += jnp.sum(a_ref[...] * b_ref[...], keepdims=True)

    return _accumulating_reduce(kernel, (a, b), a.dtype, lanes)


def vima_reduce_sum(a, *, lanes: int = LANES):
    """Horizontal sum of one 8 KB vector -> scalar."""
    def kernel(a_ref, o_ref):
        @pl.when(pl.program_id(0) == 0)
        def _init():
            o_ref[...] = jnp.zeros((1,), a.dtype)

        o_ref[...] += jnp.sum(a_ref[...], keepdims=True)

    return _accumulating_reduce(kernel, (a,), a.dtype, lanes)
