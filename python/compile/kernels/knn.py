"""k-Nearest-Neighbors distance kernel (paper Sec. IV-A, *kNN*).

The hot loop of kNN is the distance computation between one test instance
and the full training set: in VIMA each training row is streamed through
the vector units while the test vector stays resident in the VIMA cache —
the operand-reuse case (one cached vector reused against a stream).

The kernel computes squared-L2 distances for a block of training rows; the
test vector is broadcast into every grid step (index map pinned to block 0),
mirroring its residency in the VIMA cache.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def knn_dist_block(test, train, *, rows_per_block: int = 64):
    """Squared L2 distance of ``test`` (F,) against ``train`` (R, F) -> (R,)."""
    (f,) = test.shape
    r, f2 = train.shape
    if f != f2:
        raise ValueError(f"feature dims mismatch: test {f} vs train {f2}")
    if r % rows_per_block != 0:
        raise ValueError(f"rows {r} not a multiple of block {rows_per_block}")

    def kernel(t_ref, tr_ref, o_ref):
        diff = tr_ref[...] - t_ref[...][None, :]
        o_ref[...] = jnp.sum(diff * diff, axis=1)

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((r,), test.dtype),
        grid=(r // rows_per_block,),
        in_specs=[
            pl.BlockSpec((f,), lambda i: (0,)),  # test vector: cache-resident
            pl.BlockSpec((rows_per_block, f), lambda i: (i, 0)),  # train: streamed
        ],
        out_specs=pl.BlockSpec((rows_per_block,), lambda i: (i,)),
        interpret=True,
    )(test, train)
