"""Pure-jnp correctness oracles for every Pallas kernel.

These are the ground truth the pytest suite asserts against; no Pallas, no
blocking — just the mathematical definition of each VIMA operation.
"""

import jax.numpy as jnp


# --- vima_alu -------------------------------------------------------------

def binop(op: str, a, b):
    return {
        "add": lambda: a + b,
        "sub": lambda: a - b,
        "mul": lambda: a * b,
        "div": lambda: a / b,
        "min": lambda: jnp.minimum(a, b),
        "max": lambda: jnp.maximum(a, b),
        "and": lambda: a & b,
        "or": lambda: a | b,
        "xor": lambda: a ^ b,
    }[op]()


def fma(a, b, c):
    return a * b + c


def broadcast(value, n, dtype):
    return jnp.full((n,), value, dtype)


def copy(a):
    return a


def dot(a, b):
    return jnp.sum(a * b).reshape(1)


def reduce_sum(a):
    return jnp.sum(a).reshape(1)


# --- stencil ---------------------------------------------------------------

def stencil_row(prev, cur, nxt, coeff_center=0.5, coeff_neighbor=0.125):
    cc = jnp.asarray(coeff_center, cur.dtype)
    cn = jnp.asarray(coeff_neighbor, cur.dtype)
    left = jnp.concatenate([jnp.zeros((1,), cur.dtype), cur[:-1]])
    right = jnp.concatenate([cur[1:], jnp.zeros((1,), cur.dtype)])
    return cc * cur + cn * (prev + nxt + left + right)


def stencil2d(x, coeff_center=0.5, coeff_neighbor=0.125):
    cc = jnp.asarray(coeff_center, x.dtype)
    cn = jnp.asarray(coeff_neighbor, x.dtype)
    p = jnp.pad(x, 1)
    return (
        cc * x
        + cn * (p[:-2, 1:-1] + p[2:, 1:-1] + p[1:-1, :-2] + p[1:-1, 2:])
    )


# --- matmul / knn / mlp ----------------------------------------------------

def matmul(a, b):
    return a @ b


def knn_dist(test, train):
    diff = train - test[None, :]
    return jnp.sum(diff * diff, axis=1)


def mlp_layer(w, x, b, relu=True):
    y = w @ x + b
    return jnp.maximum(y, 0) if relu else y
