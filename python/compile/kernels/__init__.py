"""Layer-1 Pallas kernels: the VIMA vector-unit functional model.

Every kernel mirrors the hardware decomposition the paper describes in
Sec. III-D: one VIMA instruction operates over an 8 KB vector (2048 x 32-bit
or 1024 x 64-bit elements) executed by 256 physical lanes over 8 pipelined
beats.  The Pallas grid/block structure is isomorphic to that schedule:
blocks of LANES elements, grid of VECTOR_BYTES / (LANES * dtype_size) steps.

All kernels are lowered with ``interpret=True`` — the CPU PJRT plugin cannot
execute Mosaic custom-calls; numerics are identical, timing is modelled by
the Rust cycle simulator (Layer 3), not by these kernels.
"""

from .vima_alu import (
    LANES,
    VECTOR_BYTES,
    elements_per_vector,
    vima_binop,
    vima_ternop,
    vima_broadcast,
    vima_copy,
    vima_dot,
    vima_reduce_sum,
)
from .stencil import stencil_row, stencil2d
from .matmul import matmul_tiled, MXU_TILE
from .knn import knn_dist_block
from .mlp import mlp_layer

__all__ = [
    "LANES",
    "VECTOR_BYTES",
    "elements_per_vector",
    "vima_binop",
    "vima_ternop",
    "vima_broadcast",
    "vima_copy",
    "vima_dot",
    "vima_reduce_sum",
    "stencil_row",
    "stencil2d",
    "matmul_tiled",
    "MXU_TILE",
    "knn_dist_block",
    "mlp_layer",
]
