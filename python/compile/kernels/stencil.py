"""5-point stencil convolution as a Pallas kernel (paper Sec. IV-A, *Stencil*).

The paper's Stencil kernel convolves a 5-point cross over a matrix.  In VIMA
terms each output row is produced from three input rows held in the VIMA
cache — this is exactly the data-reuse case the VIMA cache exists for
(Sec. III-E, Fig. 2): the row fetched for iteration *i* is reused by
iterations *i+1* and *i+2*.

The kernel expresses that reuse pattern directly: the input matrix is padded
by one row top/bottom, three overlapping (1, W) row views feed each output
row via shifted block index maps.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def stencil_row(prev, cur, nxt, *, coeff_center: float = 0.5, coeff_neighbor: float = 0.125):
    """One output row of the 5-point stencil from its three source rows.

    ``out[j] = cc * cur[j] + cn * (prev[j] + nxt[j] + cur[j-1] + cur[j+1])``
    with zero boundary at the row edges (j-1 / j+1 clamped out).
    """
    w = cur.shape[0]
    # Python-float coefficients are baked into the kernel as immediates
    # (Pallas rejects captured traced constants).
    cc, cn = float(coeff_center), float(coeff_neighbor)

    def kernel(p_ref, c_ref, n_ref, o_ref):
        c = c_ref[...]
        left = jnp.concatenate([jnp.zeros((1,), c.dtype), c[:-1]])
        right = jnp.concatenate([c[1:], jnp.zeros((1,), c.dtype)])
        o_ref[...] = cc * c + cn * (p_ref[...] + n_ref[...] + left + right)

    spec = pl.BlockSpec((w,), lambda: (0,))
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((w,), cur.dtype),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        interpret=True,
    )(prev, cur, nxt)


def stencil2d(x, *, coeff_center: float = 0.5, coeff_neighbor: float = 0.125):
    """Full 5-point stencil over an (H, W) matrix, zero boundary.

    Implemented as a single pallas_call with a row grid and three overlapping
    row views into the zero-padded input — the same HBM->cache schedule the
    VIMA sequencer produces (each row is fetched once, used three times).
    """
    h, w = x.shape
    cc, cn = float(coeff_center), float(coeff_neighbor)
    padded = jnp.pad(x, ((1, 1), (0, 0)))

    def kernel(p_ref, c_ref, n_ref, o_ref):
        c = c_ref[0, :]
        left = jnp.concatenate([jnp.zeros((1,), c.dtype), c[:-1]])
        right = jnp.concatenate([c[1:], jnp.zeros((1,), c.dtype)])
        o_ref[0, :] = cc * c + cn * (p_ref[0, :] + n_ref[0, :] + left + right)

    row = lambda off: pl.BlockSpec((1, w), lambda i, off=off: (i + off, 0))
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((h, w), x.dtype),
        grid=(h,),
        in_specs=[row(0), row(1), row(2)],
        out_specs=pl.BlockSpec((1, w), lambda i: (i, 0)),
        interpret=True,
    )(padded, padded, padded)
