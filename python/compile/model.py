"""Layer-2 JAX workload graphs — what the VIMA vector units compute.

Each of the paper's seven kernels (Sec. IV-A) gets a functional definition
built on the Layer-1 Pallas kernels.  These are the compute graphs that
``aot.py`` lowers to HLO text; the Rust coordinator executes them via PJRT
for the *functional* half of a simulation while the cycle model (Layer 3)
produces the *timing* half.

Vectors longer than one 8 KB VIMA vector are processed as a scanned sequence
of per-vector instructions — exactly the instruction stream the stop-and-go
dispatch protocol produces (one VIMA instruction at a time, Sec. III-C).
"""

import jax
import jax.numpy as jnp

from .kernels import (
    elements_per_vector,
    knn_dist_block,
    matmul_tiled,
    mlp_layer,
    stencil2d,
    vima_binop,
    vima_broadcast,
    vima_copy,
    vima_ternop,
)


def _as_vectors(a):
    """Reshape a flat array into (n_instructions, elems_per_8KB_vector)."""
    epv = elements_per_vector(a.dtype)
    if a.shape[0] % epv != 0:
        raise ValueError(f"array of {a.shape[0]} elems not a multiple of {epv}")
    return a.reshape(-1, epv)


def _per_vector(fn, *arrays):
    """Apply a per-8KB-vector kernel across a long array via lax.map —
    the L2 analogue of the sequencer issuing one VIMA instruction per vector."""
    vecs = [_as_vectors(a) for a in arrays]
    out = jax.lax.map(lambda args: fn(*args), tuple(vecs))
    return out.reshape(-1)


# --- the seven paper workloads ---------------------------------------------


def memset(n: int, value, dtype=jnp.int32):
    """MemSet: set all positions of a vector to a specific value."""
    epv = elements_per_vector(dtype)
    if n % epv != 0:
        raise ValueError(f"n={n} not a multiple of {epv}")
    one = vima_broadcast(value, epv, dtype)
    return jnp.tile(one, n // epv)


def memcopy(src):
    """MemCopy: stream-copy a vector to a new location."""
    return _per_vector(vima_copy, src)


def vecsum(a, b):
    """VecSum: elementwise sum of two vectors."""
    return _per_vector(lambda x, y: vima_binop("add", x, y), a, b)


def stencil(x):
    """Stencil: 5-point convolution over a matrix (zero boundary)."""
    return stencil2d(x)


def matmul(a, b):
    """MatMul: square matrix multiply via MXU-shaped tiles."""
    return matmul_tiled(a, b)


def knn_distances(test_batch, train):
    """kNN hot loop: all test-x-train squared-L2 distances.

    test_batch (T, F), train (R, F) -> (T, R).  Each test vector stays
    VIMA-cache resident while the training set streams past it.
    """
    return jax.lax.map(lambda t: knn_dist_block(t, train), test_batch)


def knn_classify(test_batch, train, labels, k: int = 9, n_classes: int = 16):
    """Full kNN: distances -> top-k -> majority vote -> predicted labels (T,)."""
    dists = knn_distances(test_batch, train)
    _, idx = jax.lax.top_k(-dists, k)  # (T, k) nearest indices
    votes = labels[idx]  # (T, k)
    counts = jax.nn.one_hot(votes, n_classes, dtype=jnp.int32).sum(axis=1)
    return jnp.argmax(counts, axis=1).astype(jnp.int32)


def mlp_inference(x_batch, w1, b1, w2, b2):
    """MLP inference step: two dense layers, relu hidden, argmax output.

    x_batch (B, F); w1 (H, F); w2 (C, H) -> predicted classes (B,).
    """
    def one(x):
        h = mlp_layer(w1, x, b1, relu=True)
        logits = mlp_layer(w2, h, b2, relu=False)
        return jnp.argmax(logits).astype(jnp.int32)

    return jax.lax.map(one, x_batch)


def mlp_logits(x_batch, w1, b1, w2, b2):
    """Same forward pass but returning the raw logits (B, C) for validation."""
    def one(x):
        h = mlp_layer(w1, x, b1, relu=True)
        return mlp_layer(w2, h, b2, relu=False)

    return jax.lax.map(one, x_batch)


def saxpy(alpha, x, y):
    """Extension workload: alpha*x + y via the fused ternop (used by examples)."""
    epv = elements_per_vector(x.dtype)
    alpha_vec = vima_broadcast(alpha, epv, x.dtype)

    def one(xv, yv):
        return vima_ternop(alpha_vec, xv, yv)

    return _per_vector(one, x, y)
