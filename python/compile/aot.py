"""AOT lowering: every Layer-2 entry point -> HLO text in artifacts/.

Interchange format is HLO **text**, not ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the xla crate's bundled
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Each entry is lowered with ``return_tuple=True`` so the Rust side unwraps
with ``to_tuple1()``.  A ``manifest.json`` records every artifact's input
and output shapes/dtypes for the Rust runtime registry.

Run:  cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import functools
import json
import os
import re
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import (
    elements_per_vector,
    knn_dist_block,
    mlp_layer,
    stencil_row,
    vima_binop,
    vima_broadcast,
    vima_copy,
    vima_dot,
    vima_reduce_sum,
    vima_ternop,
)

S = jax.ShapeDtypeStruct


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# --- registry ----------------------------------------------------------------

REGISTRY = {}


def register(name, fn, *arg_specs):
    if name in REGISTRY:
        raise ValueError(f"duplicate artifact name {name}")
    REGISTRY[name] = (fn, arg_specs)


def _vec_spec(dtype):
    """One 8 KB VIMA vector of ``dtype``."""
    return S((elements_per_vector(dtype),), dtype)


DTYPES = {
    "f32": jnp.float32,
    "f64": jnp.float64,
    "i32": jnp.int32,
    "i64": jnp.int64,
}

# Per-VIMA-instruction artifacts: one HLO module per (opcode, dtype), operating
# on a single 8 KB vector — the granularity at which the Rust sequencer
# executes functional compute.
for dname, dt in DTYPES.items():
    v = _vec_spec(dt)
    for op in ("add", "sub", "mul"):
        register(f"v{op}_{dname}", functools.partial(vima_binop, op), v, v)
    if dname.startswith("f"):
        for op in ("div", "min", "max"):
            register(f"v{op}_{dname}", functools.partial(vima_binop, op), v, v)
        register(f"vfma_{dname}", vima_ternop, v, v, v)
        register(f"vdot_{dname}", vima_dot, v, v)
    else:
        for op in ("and", "or", "xor"):
            register(f"v{op}_{dname}", functools.partial(vima_binop, op), v, v)

register("vredsum_f32", vima_reduce_sum, _vec_spec(jnp.float32))
register("vmov_f32", vima_copy, _vec_spec(jnp.float32))
register("vmov_i32", vima_copy, _vec_spec(jnp.int32))

_EPV32 = elements_per_vector(jnp.float32)  # 2048


def _bcast(dtype):
    def fn(value):
        return vima_broadcast(value[0], elements_per_vector(dtype), dtype)
    return fn


register("vbcast_f32", _bcast(jnp.float32), S((1,), jnp.float32))
register("vbcast_i32", _bcast(jnp.int32), S((1,), jnp.int32))

# Kernel-level artifacts (paper Sec. IV-A shapes, scaled to artifact size).
register(
    "stencil_row_f32",
    stencil_row,
    S((_EPV32,), jnp.float32),
    S((_EPV32,), jnp.float32),
    S((_EPV32,), jnp.float32),
)
register("stencil2d_f32", model.stencil, S((64, _EPV32), jnp.float32))
register("matmul_f32", model.matmul, S((256, 256), jnp.float32), S((256, 256), jnp.float32))
register("knn_dist_f32", knn_dist_block, S((512,), jnp.float32), S((256, 512), jnp.float32))
register(
    "mlp_layer_f32",
    mlp_layer,
    S((256, 256), jnp.float32),
    S((256,), jnp.float32),
    S((256,), jnp.float32),
)

# Workload-level artifacts used by the examples / end-to-end driver.
register("vecsum_f32", model.vecsum, S((16 * _EPV32,), jnp.float32), S((16 * _EPV32,), jnp.float32))
register("memcopy_f32", model.memcopy, S((16 * _EPV32,), jnp.float32))
register("memset_i32", lambda v: model.memset(16 * _EPV32, v[0]), S((1,), jnp.int32))
register("saxpy_f32", lambda a, x, y: model.saxpy(a[0], x, y), S((1,), jnp.float32),
         S((8 * _EPV32,), jnp.float32), S((8 * _EPV32,), jnp.float32))
register(
    "knn_classify_i32",
    functools.partial(model.knn_classify, k=9, n_classes=16),
    S((32, 128), jnp.float32),
    S((1024, 128), jnp.float32),
    S((1024,), jnp.int32),
)
register(
    "mlp_inference_i32",
    model.mlp_inference,
    S((32, 256), jnp.float32),   # x batch
    S((256, 256), jnp.float32),  # w1
    S((256,), jnp.float32),      # b1
    S((16, 256), jnp.float32),   # w2
    S((16,), jnp.float32),       # b2
)
register(
    "mlp_logits_f32",
    model.mlp_logits,
    S((32, 256), jnp.float32),
    S((256, 256), jnp.float32),
    S((256,), jnp.float32),
    S((16, 256), jnp.float32),
    S((16,), jnp.float32),
)


# --- driver --------------------------------------------------------------------


def _spec_json(s):
    return {"shape": list(s.shape), "dtype": jnp.dtype(s.dtype).name}


def lower_one(name: str, out_dir: str) -> dict:
    fn, specs = REGISTRY[name]
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    out_aval = jax.eval_shape(fn, *specs)
    outs = jax.tree_util.tree_leaves(out_aval)
    return {
        "inputs": [_spec_json(s) for s in specs],
        "outputs": [_spec_json(s) for s in outs],
        "hlo_bytes": len(text),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="regex filter on artifact names")
    args = ap.parse_args()

    jax.config.update("jax_enable_x64", True)  # i64/f64 VIMA ops
    os.makedirs(args.out_dir, exist_ok=True)

    names = sorted(REGISTRY)
    if args.only:
        names = [n for n in names if re.search(args.only, n)]
    manifest = {}
    for i, name in enumerate(names):
        manifest[name] = lower_one(name, args.out_dir)
        print(f"[{i + 1}/{len(names)}] {name}: {manifest[name]['hlo_bytes']} chars", flush=True)

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)

    # TSV manifest for the Rust runtime (parsed in-tree, no JSON dependency):
    # name<TAB>inputs<TAB>outputs, each side dtype:dim,dim,... joined by ';'.
    def side(specs):
        return ";".join(
            f"{s['dtype']}:{','.join(str(d) for d in s['shape'])}" for s in specs
        ) or "-"

    with open(os.path.join(args.out_dir, "manifest.tsv"), "w") as f:
        f.write("# name\tinputs\toutputs\n")
        for name in names:
            m = manifest[name]
            f.write(f"{name}\t{side(m['inputs'])}\t{side(m['outputs'])}\n")
    print(f"wrote {len(names)} artifacts + manifest.[json|tsv] to {args.out_dir}")


if __name__ == "__main__":
    main()
