"""AOT pipeline checks: every registered artifact lowers to parseable HLO text
and the manifest faithfully records its signature."""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import pytest

from compile import aot


class TestRegistry:
    def test_registry_is_nonempty_and_unique(self):
        assert len(aot.REGISTRY) >= 40
        # names are the artifact filenames; they must be filesystem-safe
        for name in aot.REGISTRY:
            assert name.replace("_", "").isalnum(), name

    def test_every_dtype_has_core_ops(self):
        for d in ("f32", "f64", "i32", "i64"):
            for op in ("add", "sub", "mul"):
                assert f"v{op}_{d}" in aot.REGISTRY

    def test_float_ops_have_div_and_fma(self):
        for d in ("f32", "f64"):
            assert f"vdiv_{d}" in aot.REGISTRY
            assert f"vfma_{d}" in aot.REGISTRY
            assert f"vdot_{d}" in aot.REGISTRY

    def test_int_ops_have_bitwise(self):
        for d in ("i32", "i64"):
            for op in ("and", "or", "xor"):
                assert f"v{op}_{d}" in aot.REGISTRY

    def test_workload_artifacts_present(self):
        for name in (
            "vecsum_f32",
            "memcopy_f32",
            "memset_i32",
            "stencil2d_f32",
            "matmul_f32",
            "knn_dist_f32",
            "knn_classify_i32",
            "mlp_inference_i32",
            "mlp_logits_f32",
            "saxpy_f32",
        ):
            assert name in aot.REGISTRY, name


class TestLowering:
    @pytest.mark.parametrize("name", ["vadd_f32", "vdot_f64", "vxor_i32", "vbcast_f32"])
    def test_instruction_artifact_lowers(self, name, tmp_path):
        meta = aot.lower_one(name, str(tmp_path))
        text = (tmp_path / f"{name}.hlo.txt").read_text()
        assert text.startswith("HloModule"), text[:80]
        assert meta["hlo_bytes"] == len(text)
        # the entry computation must return a tuple (rust unwraps to_tuple1)
        assert "ENTRY" in text

    def test_manifest_shapes_match_registry(self, tmp_path):
        meta = aot.lower_one("mlp_logits_f32", str(tmp_path))
        assert [tuple(i["shape"]) for i in meta["inputs"]] == [
            (32, 256),
            (256, 256),
            (256,),
            (16, 256),
            (16,),
        ]
        assert meta["outputs"] == [{"shape": [32, 16], "dtype": "float32"}]

    def test_vector_artifacts_are_8kb(self):
        """Every per-instruction artifact operates on exactly one 8 KB vector."""
        for name, (_, specs) in aot.REGISTRY.items():
            if not name.startswith("v") or name.startswith("vecsum"):
                continue
            for s in specs:
                if len(s.shape) == 1 and s.shape[0] > 1:
                    nbytes = s.shape[0] * jnp.dtype(s.dtype).itemsize
                    assert nbytes == 8192, f"{name}: operand is {nbytes} B"


class TestArtifactsDir:
    """Validates the artifacts/ directory produced by `make artifacts`."""

    ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

    @pytest.fixture(autouse=True)
    def _skip_without_artifacts(self):
        if not os.path.exists(os.path.join(self.ARTIFACTS, "manifest.json")):
            pytest.skip("run `make artifacts` first")

    def test_manifest_covers_registry(self):
        with open(os.path.join(self.ARTIFACTS, "manifest.json")) as f:
            manifest = json.load(f)
        missing = set(aot.REGISTRY) - set(manifest)
        assert not missing, f"artifacts stale, missing {missing}: re-run make artifacts"

    def test_all_hlo_files_exist_and_parse_header(self):
        with open(os.path.join(self.ARTIFACTS, "manifest.json")) as f:
            manifest = json.load(f)
        for name in manifest:
            path = os.path.join(self.ARTIFACTS, f"{name}.hlo.txt")
            assert os.path.exists(path), path
            with open(path) as fh:
                assert fh.read(9) == "HloModule"
