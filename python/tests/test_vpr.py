"""Structural checks for the pure-Python ``.vpr`` emitter (compile/vpr.py).

The authoritative round-trip pin lives on the Rust side
(``rust/tests/program_format.rs``: every committed golden parses and lowers
bit-identically on both backends).  These tests keep the emitter honest
standalone: a lightweight mirror of the Rust parser's validation rules runs
over every program ``compile.vpr`` can emit, so drift in the emitted text is
caught without a Rust toolchain.  No JAX needed — the emitter is pure
Python by design.
"""

import pytest

from compile import vpr

# (mnemonic, num_srcs, writes_vector) — mirrors rust/src/program/mod.rs
# MNEMONICS x VimaOp::num_srcs/writes_vector.
MNEMONICS = {
    "vim2k_adds": (2, True),
    "vim2k_subs": (2, True),
    "vim2k_muls": (2, True),
    "vim2k_divs": (2, True),
    "vim2k_fmadds": (3, True),
    "vim2k_movs": (1, True),
    "vim2k_sets": (0, True),
    "vim2k_dots": (2, False),
    "vim2k_addu": (2, True),
    "vim2k_andu": (2, True),
    "vim1k_addd": (2, True),
}
VOP_ARITY = {
    "add": (2, True), "sub": (2, True), "mul": (2, True), "div": (2, True),
    "min": (2, True), "max": (2, True), "and": (2, True), "or": (2, True),
    "xor": (2, True), "fma": (3, True), "mov": (1, True), "bcast": (0, True),
    "dot": (2, False), "redsum": (1, False),
}
DTYPES = {"i32", "i64", "f32", "f64"}


def validate(text: str):
    """Mirror of the Rust parser's structural rules; returns (allocs, n_stmts)."""
    lines = [ln.split("#")[0].split() for ln in text.splitlines()]
    lines = [ln for ln in lines if ln]
    assert lines[0] == ["vpr", "1"], "magic header must lead"
    allocs, footprint_decl, vb = {}, None, vpr.VECTOR_BYTES
    depth, body_started, n_stmts = 0, False, 0

    def operand(tok, iters):
        head, _, stride = tok.partition(":")
        name, _, off = head.partition("+")
        stride, off = int(stride or 0), int(off or 0)
        assert name in allocs, f"unknown allocation {name!r} in {tok!r}"
        base, size = allocs[name]
        assert off < size, f"offset {off} outside {name!r}"
        heap = sum(s for _, s in allocs.values())
        span = (iters - 1) * stride if iters else 0
        assert base + off + span + 8 <= heap + vb, f"operand {tok!r} walks out of footprint"

    loop_iters = []
    for ln in lines[1:]:
        kw = ln[0]
        if kw in ("name", "desc", "vector_bytes", "footprint", "loop_overhead"):
            assert not body_started and not allocs, f"{kw} must be in the header"
            if kw == "vector_bytes":
                vb = int(ln[1])
            if kw == "footprint":
                footprint_decl = int(ln[1])
        elif kw == "alloc":
            assert depth == 0 and not body_started, "alloc must precede statements"
            name, size = ln[1], int(ln[2])
            assert name not in allocs and size % vb == 0, f"bad alloc {name}"
            allocs[name] = (sum(s for _, s in allocs.values()), size)
        elif kw == "vloop":
            body_started = True
            depth += 1
            loop_iters.append(int(ln[1]))
        elif kw == "end":
            assert depth > 0, "end with no open vloop"
            depth -= 1
            loop_iters.pop()
        else:
            body_started = True
            n_stmts += 1
            iters = loop_iters[-1] if loop_iters else 0
            if kw == "host_load":
                assert len(ln) == 3 and 1 <= int(ln[2]) <= 65535
                operand(ln[1], iters)
                continue
            if kw == "vop":
                assert ln[2] in DTYPES, f"bad dtype {ln[2]}"
                nsrc, writes = VOP_ARITY[ln[1]]
                rest = ln[3:]
            else:
                nsrc, writes = MNEMONICS[kw]
                rest = ln[1:]
            if "->" in rest:
                i = rest.index("->")
                srcs, dst = rest[:i], rest[i + 1:]
                assert len(dst) == 1, "exactly one destination"
                assert writes, f"{kw} reduces to a scalar, no -> dst"
                operand(dst[0], iters)
            else:
                srcs = rest
                assert not writes, f"{kw} requires -> dst"
            assert len(srcs) == nsrc, f"{kw}: want {nsrc} srcs, got {len(srcs)}"
            for s in srcs:
                operand(s, iters)
    assert depth == 0, "unclosed vloop"
    assert n_stmts > 0, "no statements"
    if footprint_decl is not None:
        assert footprint_decl == sum(s for _, s in allocs.values())
    return allocs, n_stmts


@pytest.mark.parametrize("name", sorted(vpr.PROGRAMS))
def test_every_program_is_structurally_valid(name):
    validate(vpr.PROGRAMS[name]().to_vpr())


@pytest.mark.parametrize("name", sorted(vpr.PROGRAMS))
def test_emission_is_deterministic(name):
    assert vpr.PROGRAMS[name]().to_vpr() == vpr.PROGRAMS[name]().to_vpr()


def test_saxpy_matches_the_rust_dsl_shape():
    # The contract rust/tests/program_format.rs pins bit-exactly: same alloc
    # sizes, same statement sequence as programs::saxpy(256).
    text = vpr.saxpy().to_vpr()
    allocs, n = validate(text)
    assert [s for _, s in allocs.values()] == [8192, 256 * 8192, 256 * 8192]
    assert "vim2k_sets -> alpha" in text
    assert "vim2k_fmadds alpha x:8192 y:8192 -> y:8192" in text
    assert "footprint 4202496" in text
    assert n == 2  # sets + one fmadds statement (in a 256-iteration vloop)


def test_softmax_matches_the_rust_dsl_shape():
    text = vpr.softmax().to_vpr()
    allocs, n = validate(text)
    assert [s for _, s in allocs.values()] == [256 * 8192, 8192, 256 * 8192]
    assert n == 4  # dot, host_load, set, div per row


def test_refs_render_offsets_and_strides():
    r = vpr.Ref("buf")
    assert str(r) == "buf"
    assert str(r.walk(8192)) == "buf:8192"
    assert str(r.at(16384).walk(4)) == "buf+16384:4"
    # at/walk return new refs; the original is untouched.
    assert str(r) == "buf"


def test_alloc_sizes_are_vector_aligned_and_names_unique():
    p = vpr.Program("t", "t")
    p.alloc("a", 1)  # rounds up to one vector
    assert p.allocs == [("a", vpr.VECTOR_BYTES)]
    with pytest.raises(ValueError, match="duplicate"):
        p.alloc("a", 8192)


def test_check_mode_flags_drift(tmp_path):
    assert vpr.main(["--out-dir", str(tmp_path), "--only", "saxpy"]) == 0
    assert vpr.main(["--out-dir", str(tmp_path), "--check", "--only", "saxpy"]) == 0
    (tmp_path / "saxpy.vpr").write_text("vpr 1\n")
    assert vpr.main(["--out-dir", str(tmp_path), "--check", "--only", "saxpy"]) == 1
