"""Kernel-vs-reference correctness: the CORE numeric signal for Layer 1.

Hypothesis sweeps shapes/dtypes/ops of the Pallas kernels and asserts
allclose against the pure-jnp oracle in ``compile.kernels.ref``.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    LANES,
    VECTOR_BYTES,
    elements_per_vector,
    knn_dist_block,
    matmul_tiled,
    mlp_layer,
    stencil_row,
    stencil2d,
    vima_binop,
    vima_broadcast,
    vima_copy,
    vima_dot,
    vima_reduce_sum,
    vima_ternop,
)
from compile.kernels import ref

FLOAT_DTYPES = [jnp.float32, jnp.float64]
INT_DTYPES = [jnp.int32, jnp.int64]
FLOAT_OPS = ["add", "sub", "mul", "div", "min", "max"]
INT_OPS = ["add", "sub", "mul", "and", "or", "xor"]

HYPO = settings(max_examples=25, deadline=None)


def _tol(dtype):
    return dict(rtol=1e-5, atol=1e-5) if dtype == jnp.float32 else dict(rtol=1e-12, atol=1e-12)


# --- elementwise ALU ---------------------------------------------------------


class TestBinopFloat:
    @pytest.mark.parametrize("dtype", FLOAT_DTYPES, ids=["f32", "f64"])
    @pytest.mark.parametrize("op", FLOAT_OPS)
    def test_full_vector(self, op, dtype, rng):
        n = elements_per_vector(dtype)
        a = jnp.asarray(rng.uniform(-50, 50, n), dtype)
        b = jnp.asarray(rng.uniform(1, 50, n), dtype)  # positive: safe for div
        got = vima_binop(op, a, b)
        np.testing.assert_allclose(got, ref.binop(op, a, b), **_tol(dtype))

    @HYPO
    @given(
        op=st.sampled_from(FLOAT_OPS),
        blocks=st.integers(1, 16),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_any_block_multiple(self, op, blocks, seed):
        """Vectors of any multiple of LANES work (design-exploration sizes)."""
        rng = np.random.RandomState(seed)
        n = blocks * LANES
        a = jnp.asarray(rng.uniform(-10, 10, n), jnp.float32)
        b = jnp.asarray(rng.uniform(1, 10, n), jnp.float32)
        np.testing.assert_allclose(
            vima_binop(op, a, b), ref.binop(op, a, b), rtol=1e-5, atol=1e-5
        )

    def test_rejects_non_multiple(self):
        a = jnp.zeros(LANES + 1, jnp.float32)
        with pytest.raises(ValueError, match="not a multiple"):
            vima_binop("add", a, a)

    def test_rejects_shape_mismatch(self):
        a = jnp.zeros(LANES, jnp.float32)
        b = jnp.zeros(2 * LANES, jnp.float32)
        with pytest.raises(ValueError, match="operand mismatch"):
            vima_binop("add", a, b)

    def test_rejects_unknown_op(self):
        a = jnp.zeros(LANES, jnp.float32)
        with pytest.raises(KeyError):
            vima_binop("rsqrt", a, a)

    def test_vector_bytes_constant(self):
        """Paper Sec. III-A: one VIMA instruction = 8 KB vector."""
        assert VECTOR_BYTES == 8192
        assert elements_per_vector(jnp.float32) == 2048
        assert elements_per_vector(jnp.float64) == 1024
        assert elements_per_vector(jnp.int32) == 2048
        assert elements_per_vector(jnp.int64) == 1024


class TestBinopInt:
    @pytest.mark.parametrize("dtype", INT_DTYPES, ids=["i32", "i64"])
    @pytest.mark.parametrize("op", INT_OPS)
    def test_full_vector(self, op, dtype, rng):
        n = elements_per_vector(dtype)
        a = jnp.asarray(rng.randint(-1000, 1000, n), dtype)
        b = jnp.asarray(rng.randint(-1000, 1000, n), dtype)
        np.testing.assert_array_equal(vima_binop(op, a, b), ref.binop(op, a, b))

    def test_bitwise_rejects_float(self):
        a = jnp.zeros(LANES, jnp.float32)
        with pytest.raises(TypeError, match="integer"):
            vima_binop("xor", a, a)

    def test_int_wraparound_matches_ref(self):
        """i32 overflow must wrap identically in kernel and oracle."""
        a = jnp.full(LANES, 2**31 - 1, jnp.int32)
        b = jnp.ones(LANES, jnp.int32)
        np.testing.assert_array_equal(vima_binop("add", a, b), ref.binop("add", a, b))


class TestTernopBroadcastCopy:
    @pytest.mark.parametrize("dtype", FLOAT_DTYPES, ids=["f32", "f64"])
    def test_fma(self, dtype, rng):
        n = elements_per_vector(dtype)
        a, b, c = (jnp.asarray(rng.uniform(-5, 5, n), dtype) for _ in range(3))
        np.testing.assert_allclose(vima_ternop(a, b, c), ref.fma(a, b, c), **_tol(dtype))

    @HYPO
    @given(value=st.floats(-1e6, 1e6, allow_nan=False, width=32), blocks=st.integers(1, 8))
    def test_broadcast_f32(self, value, blocks):
        n = blocks * LANES
        got = vima_broadcast(value, n, jnp.float32)
        np.testing.assert_array_equal(got, ref.broadcast(value, n, jnp.float32))

    @HYPO
    @given(value=st.integers(-(2**31), 2**31 - 1), blocks=st.integers(1, 8))
    def test_broadcast_i32(self, value, blocks):
        n = blocks * LANES
        got = vima_broadcast(value, n, jnp.int32)
        np.testing.assert_array_equal(got, ref.broadcast(value, n, jnp.int32))

    def test_copy_roundtrip(self, rng):
        a = jnp.asarray(rng.uniform(-1, 1, 2048), jnp.float32)
        np.testing.assert_array_equal(vima_copy(a), a)


class TestReductions:
    @pytest.mark.parametrize("dtype", FLOAT_DTYPES, ids=["f32", "f64"])
    def test_dot_full_vector(self, dtype, rng):
        n = elements_per_vector(dtype)
        a = jnp.asarray(rng.uniform(-1, 1, n), dtype)
        b = jnp.asarray(rng.uniform(-1, 1, n), dtype)
        np.testing.assert_allclose(
            vima_dot(a, b), ref.dot(a, b), rtol=1e-4 if dtype == jnp.float32 else 1e-10
        )

    @HYPO
    @given(blocks=st.integers(1, 16), seed=st.integers(0, 2**31 - 1))
    def test_reduce_sum_any_length(self, blocks, seed):
        rng = np.random.RandomState(seed)
        a = jnp.asarray(rng.uniform(-1, 1, blocks * LANES), jnp.float32)
        np.testing.assert_allclose(vima_reduce_sum(a), ref.reduce_sum(a), rtol=1e-4, atol=1e-4)

    def test_dot_zero_vectors(self):
        a = jnp.zeros(2048, jnp.float32)
        assert float(vima_dot(a, a)[0]) == 0.0


# --- stencil -----------------------------------------------------------------


class TestStencil:
    def test_row_matches_ref(self, rng):
        p, c, n = (jnp.asarray(rng.uniform(-1, 1, 2048), jnp.float32) for _ in range(3))
        np.testing.assert_allclose(
            stencil_row(p, c, n), ref.stencil_row(p, c, n), rtol=1e-5, atol=1e-6
        )

    @HYPO
    @given(
        h=st.integers(2, 24),
        w_blocks=st.integers(1, 4),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_2d_matches_ref(self, h, w_blocks, seed):
        rng = np.random.RandomState(seed)
        x = jnp.asarray(rng.uniform(-1, 1, (h, w_blocks * LANES)), jnp.float32)
        np.testing.assert_allclose(stencil2d(x), ref.stencil2d(x), rtol=1e-5, atol=1e-6)

    def test_2d_boundary_is_zero_padded(self):
        """A one-hot input exposes the boundary handling exactly."""
        x = jnp.zeros((4, 256), jnp.float32).at[0, 0].set(1.0)
        out = stencil2d(x)
        expect = ref.stencil2d(x)
        np.testing.assert_allclose(out, expect, atol=1e-7)
        # corner: only the center coefficient contributes at (0,0)
        assert float(out[0, 0]) == pytest.approx(0.5)

    def test_custom_coefficients(self, rng):
        x = jnp.asarray(rng.uniform(-1, 1, (8, 512)), jnp.float32)
        np.testing.assert_allclose(
            stencil2d(x, coeff_center=1.0, coeff_neighbor=0.25),
            ref.stencil2d(x, 1.0, 0.25),
            rtol=1e-5,
            atol=1e-6,
        )


# --- matmul ------------------------------------------------------------------


class TestMatmul:
    @HYPO
    @given(
        m=st.sampled_from([128, 256]),
        n=st.sampled_from([128, 256]),
        k=st.sampled_from([128, 256, 384]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, m, n, k, seed):
        rng = np.random.RandomState(seed)
        a = jnp.asarray(rng.uniform(-1, 1, (m, k)), jnp.float32)
        b = jnp.asarray(rng.uniform(-1, 1, (k, n)), jnp.float32)
        np.testing.assert_allclose(matmul_tiled(a, b), ref.matmul(a, b), rtol=1e-4, atol=1e-3)

    def test_identity(self):
        eye = jnp.eye(128, dtype=jnp.float32)
        a = jnp.asarray(np.random.RandomState(7).rand(128, 128), jnp.float32)
        np.testing.assert_allclose(matmul_tiled(a, eye), a, rtol=1e-6)

    def test_rejects_bad_inner_dim(self):
        a = jnp.zeros((128, 128), jnp.float32)
        b = jnp.zeros((256, 128), jnp.float32)
        with pytest.raises(ValueError, match="inner dims"):
            matmul_tiled(a, b)

    def test_rejects_non_tile_multiple(self):
        a = jnp.zeros((100, 128), jnp.float32)
        b = jnp.zeros((128, 128), jnp.float32)
        with pytest.raises(ValueError, match="not a multiple"):
            matmul_tiled(a, b)


# --- knn / mlp ----------------------------------------------------------------


class TestKnnMlp:
    @HYPO
    @given(
        f=st.sampled_from([32, 128, 512]),
        r_blocks=st.integers(1, 4),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_knn_dist(self, f, r_blocks, seed):
        rng = np.random.RandomState(seed)
        t = jnp.asarray(rng.uniform(-1, 1, f), jnp.float32)
        tr = jnp.asarray(rng.uniform(-1, 1, (r_blocks * 64, f)), jnp.float32)
        np.testing.assert_allclose(
            knn_dist_block(t, tr), ref.knn_dist(t, tr), rtol=1e-4, atol=1e-4
        )

    def test_knn_self_distance_zero(self, rng):
        t = jnp.asarray(rng.uniform(-1, 1, 64), jnp.float32)
        tr = jnp.tile(t, (64, 1))
        np.testing.assert_allclose(knn_dist_block(t, tr), np.zeros(64), atol=1e-5)

    def test_knn_rejects_feature_mismatch(self):
        with pytest.raises(ValueError, match="feature dims"):
            knn_dist_block(jnp.zeros(32, jnp.float32), jnp.zeros((64, 64), jnp.float32))

    @HYPO
    @given(
        h=st.sampled_from([64, 128, 256]),
        f=st.sampled_from([64, 256]),
        relu=st.booleans(),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_mlp_layer(self, h, f, relu, seed):
        rng = np.random.RandomState(seed)
        w = jnp.asarray(rng.randn(h, f), jnp.float32)
        x = jnp.asarray(rng.randn(f), jnp.float32)
        b = jnp.asarray(rng.randn(h), jnp.float32)
        np.testing.assert_allclose(
            mlp_layer(w, x, b, relu=relu), ref.mlp_layer(w, x, b, relu), rtol=1e-4, atol=1e-4
        )

    def test_mlp_narrow_head(self, rng):
        """Output layers narrower than one row-block still work (16-class head)."""
        w = jnp.asarray(rng.randn(16, 64), jnp.float32)
        x = jnp.asarray(rng.randn(64), jnp.float32)
        b = jnp.asarray(rng.randn(16), jnp.float32)
        np.testing.assert_allclose(
            mlp_layer(w, x, b), ref.mlp_layer(w, x, b), rtol=1e-4, atol=1e-4
        )

    def test_mlp_relu_clamps(self):
        w = -jnp.eye(64, dtype=jnp.float32)
        x = jnp.ones(64, jnp.float32)
        b = jnp.zeros(64, jnp.float32)
        np.testing.assert_array_equal(mlp_layer(w, x, b, relu=True), np.zeros(64))
