"""Layer-2 workload-graph correctness and shape checks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import elements_per_vector, ref

HYPO = settings(max_examples=10, deadline=None)
EPV = elements_per_vector(jnp.float32)  # 2048


class TestStreaming:
    @HYPO
    @given(vectors=st.integers(1, 8), seed=st.integers(0, 2**31 - 1))
    def test_vecsum(self, vectors, seed):
        rng = np.random.RandomState(seed)
        n = vectors * EPV
        a = jnp.asarray(rng.uniform(-10, 10, n), jnp.float32)
        b = jnp.asarray(rng.uniform(-10, 10, n), jnp.float32)
        np.testing.assert_allclose(model.vecsum(a, b), a + b, rtol=1e-6)

    @HYPO
    @given(vectors=st.integers(1, 8), value=st.integers(-1000, 1000))
    def test_memset(self, vectors, value):
        n = vectors * elements_per_vector(jnp.int32)
        out = model.memset(n, value)
        np.testing.assert_array_equal(out, np.full(n, value, np.int32))

    def test_memset_rejects_partial_vector(self):
        with pytest.raises(ValueError, match="not a multiple"):
            model.memset(100, 1)

    def test_memcopy(self, rng):
        src = jnp.asarray(rng.uniform(-1, 1, 4 * EPV), jnp.float32)
        np.testing.assert_array_equal(model.memcopy(src), src)

    def test_saxpy(self, rng):
        x = jnp.asarray(rng.uniform(-1, 1, 2 * EPV), jnp.float32)
        y = jnp.asarray(rng.uniform(-1, 1, 2 * EPV), jnp.float32)
        # fma rounds once, mul+add twice — allow one ulp of f32 slack
        np.testing.assert_allclose(model.saxpy(2.5, x, y), 2.5 * x + y, rtol=1e-4, atol=1e-6)


class TestStencilMatmul:
    def test_stencil(self, rng):
        x = jnp.asarray(rng.uniform(-1, 1, (16, EPV)), jnp.float32)
        np.testing.assert_allclose(model.stencil(x), ref.stencil2d(x), rtol=1e-5, atol=1e-6)

    def test_matmul(self, rng):
        a = jnp.asarray(rng.uniform(-1, 1, (256, 256)), jnp.float32)
        b = jnp.asarray(rng.uniform(-1, 1, (256, 256)), jnp.float32)
        np.testing.assert_allclose(model.matmul(a, b), a @ b, rtol=1e-4, atol=1e-3)


class TestKnn:
    def test_distances_shape_and_values(self, rng):
        tb = jnp.asarray(rng.uniform(0, 1, (4, 128)), jnp.float32)
        tr = jnp.asarray(rng.uniform(0, 1, (256, 128)), jnp.float32)
        d = model.knn_distances(tb, tr)
        assert d.shape == (4, 256)
        expect = np.stack([ref.knn_dist(t, tr) for t in tb])
        np.testing.assert_allclose(d, expect, rtol=1e-4, atol=1e-4)

    def test_classify_matches_sklearn_style_oracle(self, rng):
        """Majority vote over the k nearest must match a numpy re-implementation."""
        k, n_classes = 9, 16
        tb = jnp.asarray(rng.uniform(0, 1, (8, 32)), jnp.float32)
        tr = jnp.asarray(rng.uniform(0, 1, (512, 32)), jnp.float32)
        lab = jnp.asarray(rng.randint(0, n_classes, 512), jnp.int32)
        got = model.knn_classify(tb, tr, lab, k=k, n_classes=n_classes)

        d = np.asarray(model.knn_distances(tb, tr))
        for i in range(8):
            nearest = np.argsort(d[i], kind="stable")[:k]
            votes = np.bincount(np.asarray(lab)[nearest], minlength=n_classes)
            assert int(got[i]) == int(np.argmax(votes))

    def test_classify_separable_clusters(self):
        """Test points placed on top of labeled clusters must classify exactly."""
        centers = jnp.asarray([[0.0, 0.0], [10.0, 10.0]], jnp.float32)
        train = jnp.concatenate([jnp.tile(c, (64, 1)) for c in centers])
        train = jnp.pad(train, ((0, 0), (0, 30)))  # 32 features
        labels = jnp.asarray([0] * 64 + [1] * 64, jnp.int32)
        tests = jnp.pad(centers, ((0, 0), (0, 30)))
        got = model.knn_classify(tests, train, labels, k=9, n_classes=2)
        np.testing.assert_array_equal(got, [0, 1])


class TestMlp:
    def test_logits_match_numpy(self, rng):
        B, F, H, C = 8, 64, 128, 16
        x = jnp.asarray(rng.randn(B, F), jnp.float32)
        w1 = jnp.asarray(rng.randn(H, F) * 0.1, jnp.float32)
        b1 = jnp.asarray(rng.randn(H) * 0.1, jnp.float32)
        w2 = jnp.asarray(rng.randn(C, H) * 0.1, jnp.float32)
        b2 = jnp.asarray(rng.randn(C) * 0.1, jnp.float32)
        got = model.mlp_logits(x, w1, b1, w2, b2)
        h = np.maximum(np.asarray(x) @ np.asarray(w1).T + np.asarray(b1), 0)
        expect = h @ np.asarray(w2).T + np.asarray(b2)
        np.testing.assert_allclose(got, expect, rtol=1e-3, atol=1e-3)

    def test_inference_is_argmax_of_logits(self, rng):
        B, F, H, C = 4, 64, 64, 16
        args = (
            jnp.asarray(rng.randn(B, F), jnp.float32),
            jnp.asarray(rng.randn(H, F) * 0.1, jnp.float32),
            jnp.asarray(rng.randn(H) * 0.1, jnp.float32),
            jnp.asarray(rng.randn(C, H) * 0.1, jnp.float32),
            jnp.asarray(rng.randn(C) * 0.1, jnp.float32),
        )
        preds = model.mlp_inference(*args)
        logits = model.mlp_logits(*args)
        np.testing.assert_array_equal(preds, np.argmax(np.asarray(logits), axis=1))
