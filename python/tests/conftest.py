import os
import sys

import jax
import pytest

# Allow `pytest python/tests/` from the repository root: the compile
# package lives in python/.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

# i64/f64 VIMA operand types require x64 mode (must be set before any trace).
jax.config.update("jax_enable_x64", True)


@pytest.fixture(scope="session")
def rng():
    import numpy as np

    return np.random.RandomState(0x51)
